"""Plan-bundle artifact tests: serialization, fingerprints, manifest.

The artifact layer is the contract between the offline compiler and every
future serving process, so these tests pin the properties serving relies
on: byte-determinism (content addressing must be stable across
recompiles), version rejection (loaders never guess), fingerprint
sensitivity (any graph-shaping change re-keys), and manifest dedup.
"""

import dataclasses
import json

import pytest

from repro.configs.base import get_reduced
from repro.core.artifact import (
    BUNDLE_FORMAT_VERSION,
    BundleManifest,
    PlanBundle,
    bucket_key,
    bundle_from_json,
    bundle_from_obj,
    bundle_to_json,
    bundle_to_obj,
    decode_fingerprint,
    graph_fingerprint,
    load_bundle,
    resolve_bundle,
    save_bundle,
)
from repro.core.graph import GraphBuilder
from repro.core.planner import plan_records


def _small_graph(scale: int = 1):
    b = GraphBuilder("tiny")
    x = b.input((4 * scale, 4), "x")
    h = b.op("matmul", [x], (4 * scale, 8))
    g = b.op("gelu", [h], (4 * scale, 8))
    out = b.op("proj", [g, h], (4 * scale, 2))
    b.mark_output(out)
    return b.build()


def _bundle(cfg=None, n_slots=2, max_len=64, **overrides) -> PlanBundle:
    cfg = cfg or get_reduced("qwen3-0.6b")
    g = _small_graph()
    plan = plan_records(
        g.usage_records(), graph_name=g.name, use_cache=False
    )
    fields = dict(
        fingerprint=decode_fingerprint(cfg, n_slots=n_slots, max_len=max_len),
        graph_fingerprint=graph_fingerprint(g),
        arch=cfg.name,
        n_slots=n_slots,
        max_len=max_len,
        dtype=cfg.dtype,
        plan=plan,
        order=[0, 2, 1],
        fusion_groups=[[0], [1, 2]],
        provenance={"tool": "test", "greedy_total_bytes": plan.total_size},
    )
    fields.update(overrides)
    return PlanBundle(**fields)


def test_bundle_json_round_trip():
    b = _bundle()
    b2 = bundle_from_json(bundle_to_json(b))
    assert bundle_to_obj(b2) == bundle_to_obj(b)
    assert b2.order == [0, 2, 1]
    assert b2.fusion_groups == [[0], [1, 2]]
    assert b2.plan.total_size == b.plan.total_size
    assert b2.plan.offsets == b.plan.offsets


def test_bundle_encoding_is_byte_deterministic():
    """Content addressing relies on it: the same compiled plan must encode
    to the same bytes, regardless of planning wall time."""
    b = _bundle()
    slow = dataclasses.replace(b, plan=dataclasses.replace(b.plan, plan_wall_s=1.23))
    assert bundle_to_json(b) == bundle_to_json(slow)
    assert bundle_to_json(b) == bundle_to_json(bundle_from_json(bundle_to_json(b)))


def test_bundle_rejects_unknown_version():
    obj = bundle_to_obj(_bundle())
    obj["format_version"] = BUNDLE_FORMAT_VERSION + 1
    with pytest.raises(ValueError, match="format version"):
        bundle_from_obj(obj)


def test_decode_fingerprint_covers_graph_shaping_inputs():
    cfg = get_reduced("qwen3-0.6b")
    fp = decode_fingerprint(cfg, n_slots=2, max_len=64)
    assert fp == decode_fingerprint(cfg, n_slots=2, max_len=64)
    assert fp != decode_fingerprint(cfg, n_slots=4, max_len=64)
    assert fp != decode_fingerprint(cfg, n_slots=2, max_len=128)
    assert fp != decode_fingerprint(
        dataclasses.replace(cfg, d_model=cfg.d_model * 2), n_slots=2, max_len=64
    )
    assert fp != decode_fingerprint(get_reduced("mamba2-2.7b"), n_slots=2, max_len=64)
    # the citation string cannot shape a tensor: configs differing only in
    # `source` share one bundle (the advertised bucket family)
    assert fp == decode_fingerprint(
        dataclasses.replace(cfg, source="elsewhere"), n_slots=2, max_len=64
    )


def test_graph_fingerprint_is_structural():
    g = _small_graph()
    assert graph_fingerprint(g) == graph_fingerprint(_small_graph())
    assert graph_fingerprint(g) != graph_fingerprint(_small_graph(scale=2))


def test_manifest_publish_lookup_and_dedup(tmp_path):
    cfg = get_reduced("qwen3-0.6b")
    man = BundleManifest(tmp_path)
    key = bucket_key(cfg, n_slots=2, max_len=64)
    b = _bundle(cfg)
    path = man.publish(key, b, command="pytest")
    assert path.exists()
    got = man.lookup(key)
    assert got is not None and bundle_to_obj(got) == bundle_to_obj(b)
    assert man.lookup("no-such-bucket") is None

    # a second bucket with the identical compiled payload shares one file
    other_key = bucket_key(cfg, n_slots=2, max_len=64) + "|alias"
    path2 = man.publish(other_key, b, command="pytest")
    assert path2 == path
    files = [p for p in tmp_path.glob("bundle-*.json")]
    assert len(files) == 1
    entries = man.buckets()
    assert entries[key]["file"] == entries[other_key]["file"]
    assert entries[key]["command"] == "pytest"


def test_manifest_rejects_unknown_version(tmp_path):
    (tmp_path / "manifest.json").write_text(
        json.dumps({"format_version": 99, "buckets": {}})
    )
    with pytest.raises(ValueError, match="format version"):
        BundleManifest(tmp_path).buckets()


def test_resolve_bundle_accepts_bundle_file_and_dir(tmp_path):
    cfg = get_reduced("qwen3-0.6b")
    b = _bundle(cfg)
    # passthrough
    assert resolve_bundle(b, cfg, n_slots=2, max_len=64) is b
    # single file
    f = tmp_path / "one.json"
    save_bundle(b, f)
    assert bundle_to_obj(load_bundle(f)) == bundle_to_obj(b)
    got = resolve_bundle(f, cfg, n_slots=2, max_len=64)
    assert bundle_to_obj(got) == bundle_to_obj(b)
    # manifest dir
    man_dir = tmp_path / "bundles"
    BundleManifest(man_dir).publish(
        bucket_key(cfg, n_slots=2, max_len=64), b
    )
    got = resolve_bundle(man_dir, cfg, n_slots=2, max_len=64)
    assert bundle_to_obj(got) == bundle_to_obj(b)
    # missing bucket (different serving shape) -> explicit error
    with pytest.raises(FileNotFoundError, match="no bundle"):
        resolve_bundle(man_dir, cfg, n_slots=8, max_len=64)
