"""Direct unit fuzz of the shared overlap engine (core/interval_set).

The differential harness covers the engine end-to-end through the
strategies; this pins the primitives against brute force so a future
engine bug localizes to one structure instead of a planner diff.
"""

import random

import pytest

from repro.core.interval_set import (
    BestFitArena,
    DisjointIntervalSet,
    IntervalTree,
)
from repro.core.records import TensorUsageRecord

_INF = 1 << 60


def _overlap(a, b, f, l):
    return max(a, f) <= min(b, l)


@pytest.mark.parametrize("seed", range(20))
def test_disjoint_interval_set_matches_bruteforce(seed):
    rng = random.Random(seed)
    stored: list[tuple[int, int]] = []
    s = DisjointIntervalSet()
    for _ in range(200):
        f = rng.randrange(200)
        l = f + rng.randrange(8)
        brute_hit = any(_overlap(a, b, f, l) for a, b in stored)
        assert s.overlaps(f, l) == brute_hit
        if not brute_hit:
            # gap query is only defined for non-overlapping probes
            before = [f - b - 1 for a, b in stored if b < f]
            after = [a - l - 1 for a, b in stored if a > l]
            brute_gap = min(before + after, default=_INF)
            assert s.smallest_gap(f, l) == brute_gap
            if rng.random() < 0.5:
                s.add(f, l)
                stored.append((f, l))
    assert len(s) == len(stored)


@pytest.mark.parametrize("seed", range(20))
def test_interval_tree_matches_bruteforce(seed):
    rng = random.Random(seed)
    tree = IntervalTree()
    stored: list[tuple[int, int, int]] = []
    for i in range(300):
        if rng.random() < 0.7:
            a = rng.randrange(120)
            b = a + rng.randrange(20)
            tree.insert(a, b, i)
            stored.append((a, b, i))
        f = rng.randrange(120)
        l = f + rng.randrange(20)
        got = sorted(tree.overlapping(f, l))
        want = sorted(i for a, b, i in stored if _overlap(a, b, f, l))
        assert got == want
    assert len(tree) == len(stored)


def test_interval_tree_deterministic_shape():
    """Same insertion sequence -> same enumeration order (priorities are a
    deterministic stream; plans must not vary across runs)."""
    def build():
        t = IntervalTree()
        for i in range(50):
            t.insert((i * 7) % 23, (i * 7) % 23 + 3, i)
        return t.overlapping(0, 30)

    assert build() == build()


@pytest.mark.parametrize("first_fit", [False, True])
@pytest.mark.parametrize("seed", range(10))
def test_best_fit_arena_never_overlaps(seed, first_fit):
    rng = random.Random(seed)
    arena = BestFitArena(first_fit=first_fit)
    recs = []
    for i in range(120):
        a = rng.randrange(40)
        b = a + rng.randrange(6)
        recs.append(TensorUsageRecord(a, b, rng.randrange(1, 100), tensor_id=i))
        arena.place(recs[-1])
    for i, x in enumerate(recs):
        xo = arena.offsets[x.tensor_id]
        assert xo >= 0 and xo + x.size <= arena.total
        for y in recs[i + 1:]:
            if x.overlaps(y):
                yo = arena.offsets[y.tensor_id]
                assert xo + x.size <= yo or yo + y.size <= xo


def test_best_fit_arena_fills_gaps():
    # two pinned records leave a [100, 200) hole at ops 0-1; a 100-byte
    # record must land exactly in it
    arena = BestFitArena()
    lo = TensorUsageRecord(0, 3, 100, tensor_id=0)
    hi = TensorUsageRecord(0, 3, 50, tensor_id=1)
    arena.place_at(lo, 0)
    arena.place_at(hi, 200)
    fit = TensorUsageRecord(0, 1, 100, tensor_id=2)
    assert arena.place(fit) == 100
    assert arena.total == 250
    # a record too big for the hole appends at the end
    big = TensorUsageRecord(1, 2, 128, tensor_id=3)
    assert arena.place(big) == 250
