"""Pallas kernels vs pure-jnp oracles (interpret mode, shape/dtype sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import flash_decode
from repro.kernels.ops import flash_decode_auto
from repro.kernels.ref import flash_decode_ref, ssd_chunk_ref
from repro.kernels.ssd_chunk import ssd_chunk


def _mk_decode(key, B, KV, G, D, T, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    q = (jax.random.normal(k1, (B, KV, G, D)) * 0.5).astype(dtype)
    k = (jax.random.normal(k2, (B, T, KV, D)) * 0.5).astype(dtype)
    v = (jax.random.normal(k3, (B, T, KV, D)) * 0.5).astype(dtype)
    lengths = jax.random.randint(k4, (B,), 1, T + 1, jnp.int32)
    return q, k, v, lengths


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize(
    "B,KV,G,D,T,block_t",
    [
        (2, 2, 2, 64, 256, 128),
        (1, 1, 4, 128, 300, 128),  # T not a multiple of block_t
        (3, 4, 1, 64, 128, 128),   # MHA (G=1)
        (2, 1, 8, 64, 1024, 512),  # MQA-ish, long cache
    ],
)
def test_flash_decode_matches_ref(B, KV, G, D, T, block_t, dtype):
    dt = jnp.dtype(dtype)
    q, k, v, lengths = _mk_decode(jax.random.PRNGKey(0), B, KV, G, D, T, dt)
    got = flash_decode(q, k, v, lengths, block_t=block_t, interpret=True)
    want = flash_decode_ref(q, k, v, lengths)
    tol = 2e-6 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_flash_decode_short_lengths():
    """Rows with length=1 must attend to exactly one position."""
    B, KV, G, D, T = 2, 1, 2, 64, 256
    q, k, v, _ = _mk_decode(jax.random.PRNGKey(1), B, KV, G, D, T, jnp.float32)
    lengths = jnp.array([1, T], jnp.int32)
    got = flash_decode(q, k, v, lengths, block_t=128, interpret=True)
    # row 0 attends only position 0: every query head returns v[0, 0, kv=0]
    want = np.broadcast_to(np.asarray(v[0, 0, 0]), (G, D))
    np.testing.assert_allclose(
        np.asarray(got[0, 0]), want, rtol=1e-5, atol=1e-5
    )


def test_flash_decode_auto_blocks():
    B, KV, G, D, T = 1, 2, 2, 128, 640
    q, k, v, lengths = _mk_decode(jax.random.PRNGKey(2), B, KV, G, D, T, jnp.float32)
    got = flash_decode_auto(q, k, v, lengths, interpret=True)
    want = flash_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def _mk_ssd(key, B, L, H, P, N, dtype):
    ks = jax.random.split(key, 6)
    x = (jax.random.normal(ks[0], (B, L, H, P)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    dA = -jnp.exp(jax.random.normal(ks[2], (B, L, H)) * 0.3) * dt
    Bm = (jax.random.normal(ks[3], (B, L, H, N)) * 0.5).astype(dtype)
    Cm = (jax.random.normal(ks[4], (B, L, H, N)) * 0.5).astype(dtype)
    state = (jax.random.normal(ks[5], (B, H, P, N)) * 0.5).astype(jnp.float32)
    return x, dt.astype(jnp.float32), dA.astype(jnp.float32), Bm, Cm, state


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize(
    "B,L,H,P,N",
    [
        (2, 64, 2, 32, 16),
        (1, 128, 4, 64, 128),
        (2, 256, 1, 64, 64),
    ],
)
def test_ssd_chunk_matches_ref(B, L, H, P, N, dtype):
    dt_ = jnp.dtype(dtype)
    x, dt, dA, Bm, Cm, state = _mk_ssd(jax.random.PRNGKey(0), B, L, H, P, N, dt_)
    y, ns = ssd_chunk(x, dt, dA, Bm, Cm, state, interpret=True)
    y_ref, ns_ref = ssd_chunk_ref(x, dt, dA, Bm, Cm, state)
    tol = 1e-4 if dtype == "float32" else 5e-2
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
        rtol=tol, atol=tol,
    )
    np.testing.assert_allclose(
        np.asarray(ns), np.asarray(ns_ref), rtol=tol, atol=tol
    )


def test_ssd_chunk_chained_equals_model_prefill():
    """Chaining kernel chunks must reproduce the model's SSD scan."""
    from repro.models.ssm import mamba_prefill, mamba_init

    B, S, D = 1, 128, 64
    key = jax.random.PRNGKey(3)
    p = mamba_init(key, D, expand=2, head_dim=32, ngroups=1, dstate=16,
                   conv=4, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (B, S, D)) * 0.1
    out_model, (conv_state, final_state) = mamba_prefill(
        p, x, expand=2, head_dim=32, ngroups=1, dstate=16, conv=4, chunk=32
    )
    assert bool(jnp.isfinite(out_model).all())
    assert final_state.shape == (B, 4, 32, 16)
