"""Reproduction fidelity vs the paper's Tables 1–2.

Strategy-independent columns (Naive, Lower Bound) validate the graph
reconstructions; strategy columns validate the algorithms. MobileNet
v1/v2 and Inception v3 are held to tight tolerances; PoseNet is close;
DeepLab v3 / BlazeFace graphs deviate from the (unpublished) TFLite
flatbuffers the paper used — for those we check the paper's *qualitative*
claims on our graphs instead (see EXPERIMENTS.md discussion).
"""

import pytest

from repro.core import baselines, offsets, shared_objects
from repro.core.records import naive_consumption, offsets_lower_bound, shared_objects_lower_bound
from repro.models.convnets import PAPER_NETWORKS, PAPER_TABLE1, PAPER_TABLE2

MB = 2**20
FAITHFUL = ["mobilenet_v1", "mobilenet_v2", "inception_v3"]
CLOSE = ["posenet"]


@pytest.fixture(scope="module")
def recs():
    return {n: fn().usage_records() for n, fn in PAPER_NETWORKS.items()}


@pytest.mark.parametrize("net", FAITHFUL)
def test_naive_and_lb_match_paper(recs, net):
    naive = naive_consumption(recs[net]) / MB
    assert naive == pytest.approx(PAPER_TABLE1["naive"][net], rel=0.015)
    lb_off = offsets_lower_bound(recs[net]) / MB
    assert lb_off == pytest.approx(PAPER_TABLE2["lower_bound"][net], rel=0.001)
    lb_so = shared_objects_lower_bound(recs[net]) / MB
    assert lb_so == pytest.approx(PAPER_TABLE1["lower_bound"][net], rel=0.01)


@pytest.mark.parametrize("net", CLOSE)
def test_posenet_close(recs, net):
    naive = naive_consumption(recs[net]) / MB
    assert naive == pytest.approx(PAPER_TABLE1["naive"][net], rel=0.05)
    lb_off = offsets_lower_bound(recs[net]) / MB
    assert lb_off == pytest.approx(PAPER_TABLE2["lower_bound"][net], rel=0.05)


@pytest.mark.parametrize("net", FAITHFUL)
def test_offsets_gbs_matches_paper(recs, net):
    """Paper Table 2 row 1 — Greedy-by-Size hits the exact reported MB."""
    got = offsets.greedy_by_size_offsets(recs[net]).total_size / MB
    assert got == pytest.approx(PAPER_TABLE2["greedy_by_size"][net], rel=0.001)


@pytest.mark.parametrize("net", FAITHFUL + CLOSE)
def test_offsets_gbs_hits_lower_bound(recs, net):
    """Paper §6: GBS achieves the offsets lower bound on these nets."""
    got = offsets.greedy_by_size_offsets(recs[net]).total_size
    assert got == offsets_lower_bound(recs[net])


def test_prior_work_rows_match_paper(recs):
    """Our reimplementations of Lee'19 Greedy reproduce the paper's
    prior-work rows on the faithful graphs (Table 2 row 3)."""
    expect = {"mobilenet_v1": 6.125, "mobilenet_v2": 6.508, "inception_v3": 10.624}
    for net, mb in expect.items():
        got = baselines.tflite_greedy_in_order_offsets(recs[net]).total_size / MB
        assert got == pytest.approx(mb, rel=0.001), net


def test_mcf_rows_match_paper(recs):
    """Min-cost-flow (Lee'19) reproduces the paper's Table 1 values on
    MobileNet v1/v2."""
    expect = {"mobilenet_v1": 5.359, "mobilenet_v2": 7.513}
    for net, mb in expect.items():
        got = baselines.min_cost_flow_assignment(recs[net]).total_size / MB
        assert got == pytest.approx(mb, rel=0.001), net


def test_shared_objects_gbsi_table1(recs):
    """GBS-Improved on the faithful nets is within 3.5% of the paper's
    Table 1 (exact on MobileNet v1 / Inception v3)."""
    for net in FAITHFUL:
        got = shared_objects.greedy_by_size_improved(recs[net]).total_size / MB
        want = PAPER_TABLE1["greedy_by_size_improved"][net]
        assert got == pytest.approx(want, rel=0.035), net


@pytest.mark.parametrize("net", sorted(PAPER_NETWORKS))
def test_qualitative_claims_all_nets(recs, net):
    """Paper's qualitative claims hold on every graph (incl. the two
    approximate reconstructions)."""
    rs = recs[net]
    gbs_off = offsets.greedy_by_size_offsets(rs).total_size
    assert gbs_off <= 1.10 * offsets_lower_bound(rs)  # §6: LB or within 8%
    gbsi = shared_objects.greedy_by_size_improved(rs).total_size
    gbs = shared_objects.greedy_by_size(rs).total_size
    assert gbsi <= gbs  # §4.4
    naive = naive_consumption(rs)
    assert naive / gbs_off >= 3.0  # order-of-magnitude reductions