"""Serving-engine tests: continuous batching correctness + memory report.

The reference for each request is single-request decoding (B=1) with the
same params — the engine must produce identical greedy tokens even when
requests share a batch, arrive staggered, and reuse slots (active-mask
and per-slot-position correctness, incl. frozen mamba states).
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_reduced
from repro.models.api import Model
from repro.runtime.engine import InferenceEngine


def _teacher_forced_logits(cfg, params, prompt, emitted):
    """B=1 decode replaying ``prompt + emitted`` (the ENGINE's trajectory);
    returns the logits used to choose each emitted token. Comparing in
    teacher-forced mode sidesteps CPU XLA's non-bitwise-deterministic
    reductions: a numeric argmax tie in the engine would otherwise send
    the reference down a different trajectory entirely."""
    model = Model.for_config(cfg)
    caches = model.init_cache(1, 64)
    decode = jax.jit(
        lambda p, t, c, pos, act: model.decode_step(p, t, c, pos, active=act)
    )
    act = jnp.ones((1,), bool)
    seq = list(prompt) + list(emitted)
    step_logits = []
    for pos, t in enumerate(seq[:-1]):
        logits, caches = decode(
            params, jnp.asarray([[t]], jnp.int32), caches,
            jnp.asarray([pos], jnp.int32), act,
        )
        if pos >= len(prompt) - 1:
            step_logits.append(np.asarray(logits)[0])
    return step_logits


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-2.7b", "zamba2-7b"])
def test_engine_matches_single_request_reference(arch):
    cfg = get_reduced(arch)
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (4, 6, 3)]
    max_new = 5

    engine = InferenceEngine(cfg, params, n_slots=2, max_len=64)
    for pr in prompts:
        engine.submit(pr, max_new_tokens=max_new)
    done = engine.run_until_done()
    assert len(done) == len(prompts)
    by_id = {r.request_id: r for r in done}

    for rid, pr in enumerate(prompts):
        got = by_id[rid].tokens
        ref_logits = _teacher_forced_logits(cfg, params, pr, got)
        assert len(ref_logits) == len(got)
        for i, (g, row) in enumerate(zip(got, ref_logits)):
            w = int(row.argmax())
            if g == w:
                continue
            # the engine's pick must be within float noise of the
            # reference's best at the SAME state (numeric argmax tie)
            gap = float(row[w]) - float(row[g])
            assert gap < 1e-3, (
                f"{arch} req {rid} step {i}: engine chose {g}, reference "
                f"argmax {w}, logit gap {gap} too large to be a tie"
            )


def test_engine_slot_reuse_is_interval_valid():
    """Slot reuse must respect usage intervals — the §4 invariant at the
    request level (no two requests share a slot while both in flight)."""
    cfg = get_reduced("qwen3-0.6b")
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = InferenceEngine(cfg, params, n_slots=2, max_len=64)
    rng = np.random.default_rng(1)
    for _ in range(5):
        engine.submit(rng.integers(0, cfg.vocab, size=4).astype(np.int32),
                      max_new_tokens=3)
    done = engine.run_until_done()
    assert len(done) == 5
    assert len(engine.slot_log) == 5
    by_slot: dict[int, list[tuple[int, int]]] = {}
    for slot, first, last, rid in engine.slot_log:
        by_slot.setdefault(slot, []).append((first, last))
    reused = any(len(v) > 1 for v in by_slot.values())
    assert reused, "with 5 requests and 2 slots, slots must be reused"
    for slot, ivals in by_slot.items():
        ivals.sort()
        for (f1, l1), (f2, l2) in zip(ivals, ivals[1:]):
            assert l1 <= f2, f"slot {slot}: intervals {ivals} overlap"


def test_sampling_slots_with_identical_logits_can_diverge():
    """Regression: per-slot default_rng(self._wave) seeded every slot in a
    wave identically, so equal logits always produced equal tokens. The
    engine-owned generator must let consecutive draws differ."""
    cfg = get_reduced("qwen3-0.6b")
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = InferenceEngine(cfg, params, n_slots=2, max_len=32,
                             greedy=False, sample_seed=0)
    row = np.zeros(cfg.vocab, np.float32)  # identical (flat) logits
    draws = [engine._sample_token(row) for _ in range(32)]
    assert len(set(draws)) > 1, "identical logits must not pin the sample"
    # a fixed seed still makes whole runs reproducible
    engine2 = InferenceEngine(cfg, params, n_slots=2, max_len=32,
                              greedy=False, sample_seed=0)
    assert [engine2._sample_token(row) for _ in range(32)] == draws
    # and a different seed gives a different trajectory
    engine3 = InferenceEngine(cfg, params, n_slots=2, max_len=32,
                              greedy=False, sample_seed=1)
    assert [engine3._sample_token(row) for _ in range(32)] != draws


def test_engine_accepts_pre_searched_graph():
    """The outer search hands the engine a reordered/fused graph through
    ``PlanSession.from_spec``; the engine plans it instead of the
    default-order trace (decode outputs are unchanged — the plan is a
    memory artifact, not an executor)."""
    import jax.numpy as jnp

    from repro.core.fusion_search import fusion_search
    from repro.core.unified import PlanSession, PlanSpec
    from repro.trace.jaxpr_liveness import trace_graph

    cfg = get_reduced("qwen3-0.6b")
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_slots, max_len = 2, 32
    caches = model.init_cache(n_slots, max_len)
    graph = trace_graph(
        lambda p, t, c, pos, act: model.decode_step(p, t, c, pos, active=act),
        params,
        jnp.zeros((n_slots, 1), jnp.int32),
        caches,
        jnp.zeros((n_slots,), jnp.int32),
        jnp.ones((n_slots,), bool),
        name=f"{cfg.name}-decode",
    )
    searched = fusion_search(graph)
    engine = InferenceEngine(
        cfg, params, n_slots=n_slots, max_len=max_len,
        session=PlanSession.from_spec(PlanSpec(graph=searched.graph)),
    )
    plan = engine.memory_report.activation_plan
    assert plan.total_size == searched.plan.total_size
    assert plan.total_size <= searched.baseline_plan.total_size
    # the engine still serves correctly off the searched plan
    engine.submit(np.arange(4, dtype=np.int32), max_new_tokens=3)
    done = engine.run_until_done()
    assert len(done) == 1 and len(done[0].tokens) == 3


def test_legacy_plan_source_kwargs_warn():
    """The pre-unified kwargs keep working behind a DeprecationWarning
    (the shim maps them onto a PlanSession)."""
    from repro.core.unified import PlanSession, PlanSpec

    cfg = get_reduced("qwen3-0.6b")
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.deprecated_call(match="session=PlanSession"):
        legacy = InferenceEngine(cfg, params, n_slots=2, max_len=32,
                                 plan_strategy="greedy_by_size")
    new = InferenceEngine(
        cfg, params, n_slots=2, max_len=32,
        session=PlanSession.from_spec(PlanSpec(strategy="greedy_by_size")),
    )
    assert (
        legacy.memory_report.activation_plan.strategy
        == new.memory_report.activation_plan.strategy
        == "greedy_by_size"
    )


def test_engine_memory_report():
    cfg = get_reduced("qwen3-0.6b")
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = InferenceEngine(cfg, params, n_slots=2, max_len=32)
    rep = engine.memory_report
    plan = rep.activation_plan
    assert plan.total_size <= plan.naive_size
    assert plan.total_size >= plan.lower_bound
    # on this tiny config the plan should be essentially optimal AND a
    # real reduction vs naive co-residency
    assert plan.fraction_of_lower_bound <= 1.05, plan.summary()
    assert plan.reduction_vs_naive > 1.25, plan.summary()
    assert rep.cache_bytes_per_slot > 0
    assert "MiB" in rep.summary()
    # the unified report: the cross-step half is always planned, its slot
    # regions cover the measured per-slot cache bytes, and the engine's
    # state layout is a valid arena view over it
    assert rep.state_plan is not None
    assert rep.state_plan.n_slots == 2
    assert rep.state_plan.bytes_per_slot >= rep.cache_bytes_per_slot
    assert rep.unified_total_bytes == (
        plan.total_size + rep.state_plan.total_size
    )
    assert "unified footprint" in rep.summary()
    engine.state_layout.validate()
    assert engine.state_layout.total_size == rep.state_plan.total_size
    assert engine.unified_plan.activation is plan
    assert engine.unified_plan.state is rep.state_plan


def test_host_loop_retires_on_eos():
    """Regression (bugfix): the host loop never retired a request on EOS
    — only the max_new/max_len budgets ended it. With ``eos_id`` set, the
    request must stop at the FIRST emission of that token."""
    cfg = get_reduced("qwen3-0.6b")
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab, size=4).astype(np.int32)

    ref_engine = InferenceEngine(cfg, params, n_slots=1, max_len=64)
    ref_engine.submit(prompt, max_new_tokens=10)
    ref = list(ref_engine.run_until_done()[0].tokens)
    assert len(ref) == 10, "no eos_id: the full budget is served"

    eos = ref[2]
    engine = InferenceEngine(cfg, params, n_slots=1, max_len=64,
                             eos_id=int(eos))
    engine.submit(prompt, max_new_tokens=10)
    got = list(engine.run_until_done()[0].tokens)
    assert got == ref[: ref.index(eos) + 1], (
        "request must retire at the first EOS emission, inclusive"
    )


def test_run_until_done_surfaces_exhausted_waves():
    """Regression (bugfix): ``run_until_done(max_waves=...)`` silently
    returned partial results. It must warn (or raise with the flag) and
    surface the unfinished requests."""
    cfg = get_reduced("qwen3-0.6b")
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab, size=4).astype(np.int32)

    engine = InferenceEngine(cfg, params, n_slots=1, max_len=64)
    engine.submit(prompt, max_new_tokens=8)
    engine.submit(prompt, max_new_tokens=8)  # queued behind the one slot
    with pytest.warns(RuntimeWarning, match="exhausted max_waves"):
        done = engine.run_until_done(max_waves=5)
    unfinished = engine.unfinished_requests()
    assert len(done) + len(unfinished) == 2
    assert len(unfinished) >= 1

    from repro.runtime.engine import WavesExhaustedError

    engine2 = InferenceEngine(cfg, params, n_slots=1, max_len=64)
    engine2.submit(prompt, max_new_tokens=8)
    engine2.submit(prompt, max_new_tokens=8)
    with pytest.raises(WavesExhaustedError) as ei:
        engine2.run_until_done(max_waves=5, raise_on_exhausted=True)
    assert len(ei.value.unfinished) >= 1

    # a sufficient budget completes silently, with nothing left over
    engine3 = InferenceEngine(cfg, params, n_slots=1, max_len=64)
    engine3.submit(prompt, max_new_tokens=8)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        done = engine3.run_until_done()
    assert len(done) == 1 and not engine3.unfinished_requests()


def _float32_softmax(row):
    # the pre-fix implementation: float32 throughout, no renormalization
    x = row - row.max()
    e = np.exp(x)
    return e / e.sum()


def test_sample_probabilities_survive_generator_choice_tolerance():
    """Regression (bugfix): the float32 ``_softmax`` produced probability
    vectors whose float64 sum drifts past ``Generator.choice``'s strict
    tolerance (~1.5e-8) and raised "probabilities do not sum to 1". The
    fixed path computes in float64 and renormalizes explicitly."""
    cfg = get_reduced("qwen3-0.6b")
    rng = np.random.default_rng(0)
    bad = None
    for _ in range(5000):
        row = rng.normal(0, 4.0, cfg.vocab).astype(np.float32)
        p32 = _float32_softmax(row)
        if abs(float(p32.astype(np.float64).sum()) - 1.0) > 3e-7:
            bad = row
            break
    assert bad is not None, "hunt failed to produce a drifted row"

    # the old path trips numpy's strict float64 tolerance
    with pytest.raises(ValueError, match="[Pp]robabilities"):
        np.random.default_rng(0).choice(
            bad.size, p=_float32_softmax(bad).astype(np.float64)
        )

    from repro.runtime import sampling

    p = sampling.softmax(bad)
    assert p.dtype == np.float64
    assert abs(float(p.sum()) - 1.0) <= 1.5e-8
    np.random.default_rng(0).choice(bad.size, p=p)  # accepted

    t = sampling.host_probs(bad, temperature=0.8, top_k=50)
    assert t.dtype == np.float64
    np.random.default_rng(0).choice(bad.size, p=t)  # accepted

    # and the engine's sampling path draws from the same row
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = InferenceEngine(cfg, params, n_slots=1, max_len=32,
                             greedy=False, sample_seed=0)
    tok = engine._sample_token(bad)
    assert 0 <= tok < cfg.vocab
