"""Unified planning facade tests: plan()/PlanSpec/UnifiedPlan/StatePlan.

The facade is the API every serving path now goes through, so these
tests pin its contracts: wrapper parity (``plan_records``/``plan_graph``
return byte-identical plans to a direct ``plan()`` call), the cross-step
state layout's §4 properties (symmetric slots, aligned disjoint leaf
slots, exact per-slot division), fingerprint behavior (bucketed specs
share the bundle fingerprint; bucket-less specs get a content hash),
the never-worse search contract through the facade, and both arenas
materializing from one object.
"""

import dataclasses
import json

import pytest

from repro.core import plan_io
from repro.core.graph import GraphBuilder
from repro.core.planner import plan_graph, plan_records
from repro.core.records import make_records
from repro.core.shared_objects import from_slot_log
from repro.core.unified import (
    PlanSession,
    PlanSpec,
    StateRecord,
    UnifiedPlan,
    plan,
    plan_state,
    state_plan_from_obj,
    state_plan_to_obj,
)
from repro.runtime.arena import Arena, ArenaLayout


def _graph(scale: int = 1):
    b = GraphBuilder("tiny")
    x = b.input((4 * scale, 4), "x")
    h = b.op("matmul", [x], (4 * scale, 8))
    g = b.op("gelu", [h], (4 * scale, 8))
    out = b.op("proj", [g, h], (4 * scale, 2))
    b.mark_output(out)
    return b.build()


def _state_records():
    return [
        StateRecord(path="['kv'][0]", shape=(2, 8, 4), dtype="float32",
                    nbytes=2 * 8 * 4 * 4),
        StateRecord(path="['kv'][1]", shape=(2, 8, 4), dtype="float32",
                    nbytes=2 * 8 * 4 * 4),
        StateRecord(path="['ssm']", shape=(2, 16), dtype="float32",
                    nbytes=2 * 16 * 4),
    ]


# ------------------------------------------------------------- wrappers


def test_plan_records_is_a_thin_wrapper_over_plan():
    records = make_records([(0, 1, 100), (1, 2, 200), (0, 2, 300)])
    via_wrapper = plan_records(records, use_cache=False)
    via_facade = plan(
        PlanSpec(records=records, use_cache=False)
    ).activation
    a = plan_io.plan_to_obj(via_wrapper)
    b = plan_io.plan_to_obj(via_facade)
    a["plan_wall_s"] = b["plan_wall_s"] = 0.0
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_plan_graph_is_a_thin_wrapper_over_plan():
    g = _graph()
    via_wrapper = plan_graph(g, use_cache=False)
    via_facade = plan(PlanSpec(graph=g, use_cache=False)).activation
    assert via_wrapper.total_size == via_facade.total_size
    assert via_wrapper.offsets == via_facade.offsets
    assert via_wrapper.graph_name == via_facade.graph_name == g.name


def test_empty_spec_rejected():
    with pytest.raises(ValueError, match="empty PlanSpec"):
        plan(PlanSpec())


def test_search_needs_a_graph():
    records = make_records([(0, 1, 100)])
    with pytest.raises(ValueError, match="needs a graph"):
        plan(PlanSpec(records=records, search=True))


# ------------------------------------------------------------ state plan


def test_plan_state_layout_properties():
    sp = plan_state(_state_records(), n_slots=2, max_len=8)
    assert sp.n_slots == 2 and sp.max_len == 8
    assert len(sp.leaves) == 3
    # leaves are packed size-descending, aligned, disjoint
    offsets = [l.offset for l in sp.leaves]
    assert offsets == sorted(offsets)
    for a, b in zip(sp.leaves, sp.leaves[1:]):
        assert a.slot_nbytes >= b.slot_nbytes
        assert b.offset >= a.offset + a.slot_nbytes
    for leaf in sp.leaves:
        assert leaf.offset % sp.alignment == 0
        assert leaf.slot_nbytes % sp.alignment == 0
    assert sp.slot_stride >= sum(l.slot_nbytes for l in sp.leaves)
    assert sp.total_size == sp.n_slots * sp.slot_stride
    assert sp.bytes_per_slot == sp.slot_stride
    # concrete offsets: slot 1's copy of a leaf is one stride later
    assert (
        sp.offset_of(1, "['ssm']") == sp.offset_of(0, "['ssm']") + sp.slot_stride
    )
    with pytest.raises(KeyError):
        sp.offset_of(0, "nope")
    with pytest.raises(IndexError):
        sp.offset_of(7, "['ssm']")


def test_plan_state_rejects_unslotted_leaves():
    bad = [StateRecord(path="x", shape=(3,), dtype="float32", nbytes=12)]
    with pytest.raises(ValueError, match="not divisible"):
        plan_state(bad, n_slots=5, max_len=8)


def test_state_plan_round_trips():
    sp = plan_state(_state_records(), n_slots=4, max_len=16)
    obj = state_plan_to_obj(sp)
    sp2 = state_plan_from_obj(json.loads(json.dumps(obj)))
    assert state_plan_to_obj(sp2) == obj
    assert sp2 == sp


def test_state_plan_is_deterministic():
    recs = _state_records()
    a = plan_state(recs, n_slots=2, max_len=8)
    b = plan_state(list(reversed(recs)), n_slots=2, max_len=8)
    assert state_plan_to_obj(a) == state_plan_to_obj(b)


# --------------------------------------------------------- unified plan


def test_unified_total_is_sum_of_halves():
    g = _graph()
    up = plan(PlanSpec(
        graph=g, state_records=_state_records(), n_slots=2, max_len=8,
        use_cache=False,
    ))
    assert up.activation is not None and up.state is not None
    assert up.total_size == up.activation.total_size + up.state.total_size
    # the unified footprint never exceeds the independently planned halves
    act_alone = plan_graph(g, use_cache=False).total_size
    state_alone = plan_state(_state_records(), n_slots=2, max_len=8).total_size
    assert up.total_size <= act_alone + state_alone
    assert "unified footprint" in up.summary()


def test_both_arenas_materialize_from_one_object():
    import numpy as np

    up = plan(PlanSpec(
        graph=_graph(), state_records=_state_records(), n_slots=2, max_len=8,
        use_cache=False,
    ))
    act_layout, state_layout = up.arena_layouts()
    assert (act_layout, state_layout) == ArenaLayout.from_unified(up)
    act_layout.validate()
    state_layout.validate()
    arena = Arena(state_layout)
    assert arena.nbytes == up.state.total_size
    # store/view a leaf-shaped value through the layout's dense ids
    tid, _slot, leaf, _off = up.state.flat_entries()[0]
    n = leaf.slot_nbytes // 4
    view = arena.store(tid, np.arange(n, dtype=np.float32))
    assert view.sum() == np.arange(n, dtype=np.float32).sum()


def test_spec_fingerprint_is_content_addressed():
    records = make_records([(0, 1, 100), (1, 2, 200)])
    fp = plan(PlanSpec(records=records, use_cache=False)).fingerprint
    assert fp == plan(PlanSpec(records=records, use_cache=False)).fingerprint
    bigger = make_records([(0, 1, 100), (1, 2, 300)])
    assert fp != plan(PlanSpec(records=bigger, use_cache=False)).fingerprint
    with_state = plan(PlanSpec(
        records=records, state_records=_state_records(), n_slots=2, max_len=8,
        use_cache=False,
    )).fingerprint
    assert fp != with_state


def test_bucketed_spec_shares_the_bundle_fingerprint():
    from repro.configs.base import get_reduced
    from repro.core.artifact import decode_fingerprint

    cfg = get_reduced("qwen3-0.6b")
    up = plan(PlanSpec(
        graph=_graph(), cfg=cfg, n_slots=2, max_len=64, use_cache=False,
    ))
    assert up.fingerprint == decode_fingerprint(cfg, n_slots=2, max_len=64)


def test_facade_search_is_never_worse():
    g = _graph(scale=3)
    baseline = plan(PlanSpec(graph=g, use_cache=False)).activation
    up = plan(PlanSpec(
        graph=g, search=True, search_iters=30, fusion_rounds=5,
        use_cache=False,
    ))
    assert up.activation.total_size <= baseline.total_size
    assert up.search is not None
    assert up.search.greedy_plan.total_size == baseline.total_size
    assert up.provenance["greedy_total_bytes"] == baseline.total_size
    assert up.provenance["searched_total_bytes"] is not None
    assert "search_stats" in up.provenance


# -------------------------------------------------------------- session


def test_session_takes_exactly_one_source(tmp_path):
    with pytest.raises(ValueError, match="exactly one source"):
        PlanSession()
    with pytest.raises(ValueError, match="exactly one source"):
        PlanSession(manifest_dir=tmp_path, spec=PlanSpec())


def test_session_from_spec_resolution():
    from repro.configs.base import get_reduced

    cfg = get_reduced("qwen3-0.6b")
    # knobs-only spec: the engine traces; the knobs ride along
    res = PlanSession.from_spec(PlanSpec(strategy="greedy_by_size")).resolve(
        cfg, n_slots=2, max_len=32
    )
    assert res.unified is None and res.source == "spec"
    assert res.spec.strategy == "greedy_by_size"
    assert res.max_len == 32
    # graph-carrying spec: planned immediately, bucket fingerprint
    res = PlanSession.from_spec(PlanSpec(graph=_graph())).resolve(
        cfg, n_slots=2, max_len=32
    )
    assert res.unified is not None
    assert res.unified.activation is not None


def test_session_miss_lists_compiled_buckets(tmp_path):
    from repro.configs.base import get_reduced
    from repro.core.artifact import BundleManifest, bucket_key

    cfg = get_reduced("qwen3-0.6b")
    # empty manifest
    res = PlanSession.from_manifest(tmp_path).resolve(
        cfg, n_slots=2, max_len=32
    )
    assert res.unified is None
    assert "manifest is empty" in res.warning
    # a manifest with OTHER (inadmissible: smaller pool, shorter cache)
    # buckets: the warning lists what exists
    man = BundleManifest(tmp_path)
    other_key = bucket_key(cfg, n_slots=1, max_len=16)
    (tmp_path / "manifest.json").write_text(json.dumps({
        "format_version": 1,
        "buckets": {other_key: {"file": "bundle-0.json"}},
    }))
    res = PlanSession.from_manifest(tmp_path).resolve(
        cfg, n_slots=2, max_len=32
    )
    assert res.unified is None
    assert other_key in res.warning
    assert "compiled buckets" in res.warning
    del man


# ---------------------------------------------------------- slot audit


def test_from_slot_log_accepts_valid_log():
    log = [(0, 0, 3, 0), (1, 0, 2, 1), (0, 4, 6, 2), (1, 3, 5, 3)]
    asn = from_slot_log(log, n_slots=2, slot_size=64)
    assert asn.total_size == 2 * 64
    assert asn.assignment == {0: 0, 1: 1, 2: 0, 3: 1}


def test_from_slot_log_rejects_overlap_and_bad_slot():
    with pytest.raises(ValueError, match="overlaps"):
        from_slot_log([(0, 0, 5, 0), (0, 3, 7, 1)], n_slots=2)
    with pytest.raises(ValueError, match="outside"):
        from_slot_log([(5, 0, 1, 0)], n_slots=2)


# -------------------------------------------------- executor integration


def test_executor_accepts_unified_plan():
    import jax.numpy as jnp

    from repro.runtime.executor import ArenaExecutor

    def fn(x):
        h = jnp.tanh(x @ x.T)
        return (h + 1.0).sum(axis=0)

    x = jnp.ones((8, 8), jnp.float32)
    probe = ArenaExecutor(fn, x)
    up = UnifiedPlan(activation=probe.plan, state=None, fingerprint="x")
    ex = ArenaExecutor(fn, x, plan=up)
    assert ex.plan.total_size == probe.plan.total_size
    assert ex.state_arena is None  # state-less plan: nothing materialized
    import numpy as np

    np.testing.assert_allclose(np.asarray(ex(x)), np.asarray(fn(x)), rtol=1e-6)


def test_executor_materializes_state_arena_from_unified_plan():
    """A full UnifiedPlan hands the executor the cross-step half too: a
    host arena addressed by the same leaf_view_spec cells as the engine's
    device residency, usable to store/read per-slot cache leaves."""
    import jax.numpy as jnp
    import numpy as np

    from repro.runtime.executor import ArenaExecutor

    def fn(x):
        return jnp.tanh(x @ x.T).sum(axis=0)

    x = jnp.ones((4, 4), jnp.float32)
    probe = ArenaExecutor(fn, x)
    sp = plan_state(_state_records(), n_slots=2, max_len=8)
    up = UnifiedPlan(activation=probe.plan, state=sp, fingerprint="x")
    ex = ArenaExecutor(fn, x, plan=up)
    assert ex.state_arena is not None
    assert ex.state_arena.nbytes == sp.total_size
    view = sp.leaf_view_spec()[0]
    n = view.used_nbytes // 4
    got = ex.state_arena.store(
        view.tensor_id, np.arange(n, dtype=np.float32)
    )
    np.testing.assert_array_equal(got, np.arange(n, dtype=np.float32))
