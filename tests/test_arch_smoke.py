"""Per-architecture smoke tests on REDUCED configs (CPU, tiny dims).

For every one of the 10 assigned architectures:
  * one forward pass — shape + finiteness
  * one train step — loss finite, params update
  * prefill + decode_step consistency vs teacher-forced forward — this
    exercises the KV ring buffers, mamba recurrent state, MoE routing and
    the zamba shared block end to end.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config, get_reduced
from repro.models import transformer
from repro.models.api import Model
from repro.launch.train import make_train_step
from repro.optim import adamw

B, S = 2, 24


def _batch(cfg, key, seq=S):
    tokens = jax.random.randint(key, (B, seq), 0, cfg.vocab, jnp.int32)
    b = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.n_prefix_tokens:
        b["prefix_embeds"] = (
            jax.random.normal(key, (B, cfg.n_prefix_tokens, cfg.d_model)) * 0.02
        ).astype(jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        b["frames"] = (
            jax.random.normal(key, (B, max(seq // cfg.enc_len_ratio, 1), cfg.d_model))
            * 0.02
        ).astype(jnp.dtype(cfg.dtype))
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    assert cfg.name == arch
    assert cfg.source
    # exact assigned dims
    expect = {
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "mamba2-2.7b": (64, 2560, 1, 1, 0, 50280),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab)
    assert got == expect, f"{arch}: {got} != {expect}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_is_small(arch):
    r = get_reduced(arch)
    assert r.n_layers <= 2
    assert r.d_model <= 512
    assert r.n_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch):
    cfg = get_reduced(arch)
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = jax.jit(
        lambda p, b: model.forward(p, b)
    )(params, batch)
    S_total = S + (cfg.n_prefix_tokens or 0)
    assert logits.shape == (B, S_total, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/Inf in logits"
    assert bool(jnp.isfinite(aux)), "NaN/Inf in aux loss"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_reduced(arch)
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt_state = adamw.init_state(params)
    step = jax.jit(make_train_step(model, opt_cfg, remat=True))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    new_params, opt_state, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # at least one param changed
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
        params, new_params,
    )
    assert max(jax.tree_util.tree_leaves(diffs)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    """decode_step(t) after prefill(t[:n]) must reproduce the teacher-forced
    forward logits — validates caches (ring buffers, ssm state, shared
    block) against the non-cached path."""
    cfg = get_reduced(arch)
    if cfg.n_experts:
        # GShard capacity dropping depends on sequence length, so exact
        # cached/uncached equivalence requires a no-drop capacity factor
        # (C == S). Dropping itself is causal and exercised elsewhere.
        import dataclasses

        cfg = dataclasses.replace(
            cfg, capacity_factor=cfg.n_experts / cfg.top_k
        )
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    n = S - 4  # prefill length; decode the remaining 4 tokens

    full_logits, _ = jax.jit(lambda p, b: model.forward(p, b))(params, batch)

    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :n]
    last_logits, caches = jax.jit(lambda p, b: model.prefill(p, b))(params, pre_batch)

    P = cfg.n_prefix_tokens or 0
    np.testing.assert_allclose(
        np.asarray(last_logits), np.asarray(full_logits[:, P + n - 1]),
        rtol=2e-4, atol=2e-4,
    )

    if cfg.family == "audio":
        caches = {
            "self": tuple(
                jnp.pad(c, ((0, 0), (0, 0), (0, S - n), (0, 0), (0, 0)))
                for c in caches["self"]
            ),
            "cross": caches["cross"],
        }
    else:
        caches = transformer.grow_caches(cfg, caches, S + P)
    decode = jax.jit(
        lambda p, t, c, pos: model.decode_step(p, t, c, pos)
    )
    for i in range(n, S):
        tok = batch["tokens"][:, i : i + 1]
        logits, caches = decode(params, tok, caches, jnp.asarray(P + i, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, P + i]),
            rtol=2e-4, atol=2e-4,
            err_msg=f"{arch}: decode step {i} diverged from forward",
        )
