"""Paged state subsystem tests: page-granular planning + paged decode.

The discipline mirrors the residency and scan-block differentials: the
symmetric (whole-slot-region) backend is the oracle, and the paged
backend — per-slot page tables over a fixed-page pool, allocate on
admission, free on retirement — must be BYTE-identical to it: same
tokens per request, same slot log, and every cache leaf bitwise-equal
after the run. On top of that the paged path proves its own economics
(live pool bytes track live tokens, not ``n_slots * slot_stride``) and
its own honesty (page audit via ``from_page_log``, refusal instead of
corruption when the pool runs dry, counters intact when serving a paged
bucket from a v3 bundle).
"""

import json

import jax
import numpy as np
import pytest

from repro.analysis import counters, soundness
from repro.configs.base import get_reduced
from repro.core.shared_objects import from_page_log
from repro.core.unified import (
    PagedStatePlan,
    StateRecord,
    detect_state_axes,
    plan_paged_state,
    plan_state,
    state_plan_from_obj,
    state_plan_to_obj,
)
from repro.models.api import Model
from repro.runtime.engine import InferenceEngine
from repro.runtime.paging import PagedOutOfPagesError

ARCHS = ["qwen3-0.6b", "mamba2-2.7b", "zamba2-7b"]


def _params(cfg):
    return Model.for_config(cfg).init(jax.random.PRNGKey(0))


def _prompts(cfg, sizes=(4, 6, 3, 5, 4)):
    rng = np.random.default_rng(0)
    return [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
            for n in sizes]


def _run(cfg, params, prompts, *, max_new=6, n_slots=2, max_len=64, **kw):
    engine = InferenceEngine(cfg, params, n_slots=n_slots, max_len=max_len,
                             **kw)
    for p in prompts:
        engine.submit(p, max_new_tokens=max_new)
    done = engine.run_until_done()
    tokens = {r.request_id: list(r.tokens) for r in done}
    return engine, tokens


def _assert_byte_identical(sym, paged):
    for a, b in zip(jax.tree_util.tree_leaves(sym.caches),
                    jax.tree_util.tree_leaves(paged.caches)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


# ----------------------------------------------------------- plan level


def _toy_records(n_slots=2):
    # kv-like leaf: token axis 1 of 16 rows x 32 B; ssm-like leaf:
    # length-independent (no token axis)
    return [
        StateRecord(path="kv", shape=(n_slots, 16, 8), dtype="float32",
                    nbytes=n_slots * 16 * 8 * 4),
        StateRecord(path="ssm", shape=(n_slots, 24), dtype="float32",
                    nbytes=n_slots * 24 * 4),
    ], {"kv": (0, 1), "ssm": (0, None)}


def test_plan_paged_state_geometry():
    records, axes = _toy_records()
    base = plan_state(records, n_slots=2, max_len=16)
    for page in (64, 100):  # divisor and non-divisor of the stride
        sp = plan_paged_state(records, n_slots=2, max_len=16,
                              page_size=page, axes=axes)
        assert isinstance(sp, PagedStatePlan)
        # logical layout unchanged: the §4 objective the symmetric
        # certifiers reason about
        assert sp.total_size == base.total_size
        assert sp.slot_stride == base.slot_stride
        assert sp.pages_per_slot == -(-base.slot_stride // page)
        assert sp.n_pages_pool == 2 * sp.pages_per_slot  # default pool
        assert sp.phys_total_size == (sp.n_pages_pool + 1) * page
        # pool offsets are a permutation of physical pages 1..n (0 is
        # the reserved null page)
        assert sorted(o // page for o in sp.page_offsets) == \
            list(range(1, sp.n_pages_pool + 1))
        assert not soundness.certify_state_plan(sp), "pristine must be clean"


def test_pages_needed_tracks_live_tokens():
    records, axes = _toy_records()
    sp = plan_paged_state(records, n_slots=2, max_len=16, page_size=64,
                          axes=axes)
    all_pages = set(range(sp.pages_per_slot))
    prev: set = set()
    for length in (0, 1, 4, 8, 16):
        need = set(sp.pages_needed(length))
        assert prev <= need <= all_pages, length
        prev = need
    # the ssm leaf is fully live even at length 0
    assert sp.pages_needed(0), "length-independent leaves stay mapped"
    # short requests touch a strict subset of the slot's pages
    assert sp.live_bytes(1) < sp.pages_per_slot * sp.page_size
    assert set(sp.pages_needed(sp.max_len)) <= all_pages


def test_paged_plan_serialization_round_trip():
    records, axes = _toy_records()
    sp = plan_paged_state(records, n_slots=2, max_len=16, page_size=100,
                          axes=axes)
    rt = state_plan_from_obj(state_plan_to_obj(sp))
    assert isinstance(rt, PagedStatePlan)
    assert rt == sp
    # symmetric plans keep round-tripping to the symmetric class
    sym = state_plan_from_obj(state_plan_to_obj(
        plan_state(records, n_slots=2, max_len=16)))
    assert not isinstance(sym, PagedStatePlan)


@pytest.mark.parametrize("arch", ARCHS)
def test_detect_state_axes_every_leaf_has_a_slot_axis(arch):
    cfg = get_reduced(arch)
    model = Model.for_config(cfg)
    axes = detect_state_axes(model.init_cache, n_slots=2, max_len=32)
    assert axes
    caches = jax.eval_shape(lambda: model.init_cache(2, 32))
    leaves, _ = jax.tree_util.tree_flatten_with_path(caches)
    for path, leaf in leaves:
        slot_ax, tok_ax = axes[jax.tree_util.keystr(path)]
        assert leaf.shape[slot_ax] == 2
        if tok_ax is not None:
            assert leaf.shape[tok_ax] == 32


# -------------------------------------------------- byte-identity oracle


@pytest.mark.parametrize("arch", ARCHS)
def test_paged_decode_byte_identical_to_symmetric(arch):
    """The tentpole differential: paged decode (page tables, pool
    gather/scatter, allocate-on-admit/free-on-retire with slot reuse)
    against the symmetric backend — tokens, slot log, and every cache
    leaf bitwise, on both the host loop and the scan-block path."""
    cfg = get_reduced(arch)
    params = _params(cfg)
    prompts = _prompts(cfg)
    sym, sym_tokens = _run(cfg, params, prompts)
    paged, paged_tokens = _run(cfg, params, prompts, page_size=1024)
    assert paged.state.paged and not getattr(sym.state, "paged", False)
    assert paged_tokens == sym_tokens
    assert [tuple(x) for x in paged.slot_log] == \
        [tuple(x) for x in sym.slot_log]
    _assert_byte_identical(sym, paged)

    blk_sym, blk_sym_tokens = _run(cfg, params, prompts, block_size=4)
    blk_paged, blk_paged_tokens = _run(cfg, params, prompts, block_size=4,
                                       page_size=1024)
    assert blk_paged_tokens == blk_sym_tokens == sym_tokens
    _assert_byte_identical(blk_sym, blk_paged)


def test_paged_decode_non_divisor_page_size():
    """Page sizes that do not divide the slot stride leave a partial
    tail page per slot; the unpack/pack round trip must still be exact."""
    cfg = get_reduced("qwen3-0.6b")
    params = _params(cfg)
    prompts = _prompts(cfg)
    _, ref = _run(cfg, params, prompts)
    for page in (1000, 4096):
        paged, got = _run(cfg, params, prompts, page_size=page)
        assert got == ref, f"page_size={page} diverged"
        assert paged.state.pages_live == 0, "drained engine frees all pages"


def test_paged_seeded_sampling_matches_symmetric():
    cfg = get_reduced("qwen3-0.6b")
    params = _params(cfg)
    prompts = _prompts(cfg, sizes=(4, 5))
    kw = dict(greedy=False, temperature=0.9, top_k=20, max_new=8,
              sample_seed=7)
    for extra in (dict(), dict(block_size=4)):
        sym, a = _run(cfg, params, prompts, **kw, **extra)
        paged, b = _run(cfg, params, prompts, page_size=1024, **kw, **extra)
        assert a == b, f"sampled trajectory diverged under paging ({extra})"
        _assert_byte_identical(sym, paged)


def test_slot_reuse_frees_and_recycles_pages():
    """Retirement returns a slot's pages to the pool; later admissions
    reuse them. The page log is a §4 shared-objects assignment one level
    below the slot log — ``from_page_log`` raises if any pool page
    served two requests at overlapping waves."""
    cfg = get_reduced("qwen3-0.6b")
    params = _params(cfg)
    engine, tokens = _run(cfg, params, _prompts(cfg), page_size=1024)
    assert len(tokens) == 5 and engine.n_slots == 2  # forced slot reuse
    log = engine.page_log
    assert log and all(fin >= adm for _, adm, fin, _ in log)
    sp = engine.memory_report.state_plan
    audit = from_page_log(log, state_plan=sp)
    assert len(audit.assignment) == len(log)
    by_page: dict = {}
    for page, _, _, rid in log:
        by_page.setdefault(page, set()).add(rid)
    assert any(len(rids) > 1 for rids in by_page.values()), \
        "no physical page was ever recycled across requests"
    assert engine.state.pages_live == 0
    assert engine.state.pages_live_peak > 0


def test_from_page_log_rejects_double_assignment_and_null_page():
    records, axes = _toy_records()
    sp = plan_paged_state(records, n_slots=2, max_len=16, page_size=64,
                          axes=axes)
    with pytest.raises(ValueError, match="null page"):
        from_page_log([(0, 0, 3, 0)], state_plan=sp)
    with pytest.raises(ValueError):
        from_page_log([(1, 0, 5, 0), (1, 4, 8, 1)], state_plan=sp)
    # disjoint residencies on one page are exactly what reuse looks like
    from_page_log([(1, 0, 3, 0), (1, 4, 8, 1)], state_plan=sp)


# ------------------------------------------------- pool economics/honesty


def test_live_paged_bytes_beat_symmetric_plan_at_low_fill():
    """The headline win: at <= 25% fill (1 of 4 slots, short request)
    the paged backend's live pool bytes are >= 3x smaller than the
    symmetric plan's always-allocated ``total_size``."""
    cfg = get_reduced("qwen3-0.6b")
    params = _params(cfg)
    engine, _ = _run(cfg, params, _prompts(cfg, sizes=(4,)), max_new=4,
                     n_slots=4, page_size=512)
    sp = engine.memory_report.state_plan
    peak = engine.state.pages_live_peak * sp.page_size
    assert peak > 0
    assert peak * 3 <= sp.total_size, (
        f"peak live {peak} B not 3x under symmetric {sp.total_size} B"
    )


def test_memory_report_honest_under_paging():
    cfg = get_reduced("qwen3-0.6b")
    params = _params(cfg)
    engine = InferenceEngine(cfg, params, n_slots=2, max_len=64,
                             page_size=1024)
    rep0 = engine.memory_report
    assert rep0.state_pages_total == engine.state.pages_total
    assert rep0.state_pages_live == 0
    assert rep0.state_page_size == 1024
    assert rep0.cache_bytes_per_slot == 0, "no live pages, no cache bytes"
    assert "paged" in rep0.summary()

    for p in _prompts(cfg, sizes=(4, 6)):
        engine.submit(p, max_new_tokens=6)
    engine.step()
    rep = engine.memory_report
    assert rep.state_pages_live == engine.state.pages_live > 0
    assert rep.state_live_bytes == rep.state_pages_live * 1024
    # live-page bytes per ACTIVE slot, not the symmetric per-slot stride
    assert rep.cache_bytes_per_slot == rep.state_live_bytes // 2
    assert rep.cache_bytes_per_slot < rep.state_plan.bytes_per_slot
    engine.run_until_done()
    # symmetric engines keep the fields unset
    sym = InferenceEngine(cfg, params, n_slots=2, max_len=64)
    assert sym.memory_report.state_pages_total is None
    assert sym.memory_report.state_page_size is None


# --------------------------------------------------------- pool pressure


def test_out_of_pages_refuses_without_corruption():
    """A pool sized for ~one slot serializes admissions: requests wait
    (head-of-line) instead of corrupting live slots, and every request
    still finishes with the unconstrained engine's exact tokens."""
    cfg = get_reduced("qwen3-0.6b")
    params = _params(cfg)
    # equal-length prompts -> every request needs the same page count
    prompts = _prompts(cfg, sizes=(4, 4, 4, 4))
    base, ref = _run(cfg, params, prompts, page_size=1024)
    sp = base.memory_report.state_plan
    need = len(sp.pages_needed(min(4 + 6, 64)))
    # one request always fits, two never do
    tight, got = _run(cfg, params, prompts, page_size=1024,
                      page_pool=2 * need - 1)
    assert got == ref, "pool pressure changed decoded tokens"
    assert tight.state.pages_live_peak <= 2 * need - 1
    slots_busy = [
        {s for s, a, f, _ in tight.slot_log if a <= w <= f}
        for w in range(tight._wave)
    ]
    assert all(len(s) <= 1 for s in slots_busy), "admissions not serialized"
    assert tight._wave > base._wave, "serialization must cost extra waves"
    from_page_log(tight.page_log, state_plan=tight.memory_report.state_plan)


def test_unfittable_request_raises_clear_error():
    cfg = get_reduced("qwen3-0.6b")
    params = _params(cfg)
    engine = InferenceEngine(cfg, params, n_slots=2, max_len=64,
                             page_size=1024, page_pool=1)
    engine.submit(_prompts(cfg, sizes=(4,))[0], max_new_tokens=60)
    with pytest.raises(PagedOutOfPagesError, match="paged admission refused"):
        engine.run_until_done()
    e = PagedOutOfPagesError(pages_needed=7, pages_free=1, pages_live=3,
                             pages_total=4)
    assert "7 page(s)" in str(e) and "1 of" in str(e) and "4 pool" in str(e)


def test_unfinished_requests_under_pool_pressure():
    cfg = get_reduced("qwen3-0.6b")
    params = _params(cfg)
    probe = InferenceEngine(cfg, params, n_slots=2, max_len=64,
                            page_size=1024)
    per_slot = probe.memory_report.state_plan.pages_per_slot
    engine = InferenceEngine(cfg, params, n_slots=2, max_len=64,
                             page_size=1024, page_pool=per_slot)
    for p in _prompts(cfg, sizes=(4, 5)):
        engine.submit(p, max_new_tokens=10)
    with pytest.warns(RuntimeWarning, match="exhausted"):
        engine.run_until_done(max_waves=4)
    assert len(engine.unfinished_requests()) >= 1
    assert engine.state.pages_live <= per_slot


# ------------------------------------------------------- artifact serving


def test_paged_bundle_serves_with_zero_work(tmp_path):
    """Zero-trace / zero-plan / zero-compile serving of a PAGED bucket
    from a v3 manifest: the page knobs join the serve fingerprint and
    bucket key, the AOT pack carries ``paged_*`` executables, and the
    engine pays no compiles serving them."""
    from repro.core.artifact import parse_bucket_key, serve_fingerprint
    from repro.core.unified import PlanSession
    from repro.launch.compile import compile_and_publish

    assert serve_fingerprint(page_size=1024) is not None
    cfg = get_reduced("qwen3-0.6b")
    params = _params(cfg)
    res = compile_and_publish(cfg, str(tmp_path), n_slots=2, max_len=64,
                              page_size=1024, measure_xla=False)
    assert isinstance(res.bundle.state_plan, PagedStatePlan)
    assert {"paged_decode", "paged_reset"} <= set(
        res.bundle.executables.entries
    )
    keys = list(json.loads(
        (tmp_path / "manifest.json").read_text())["buckets"])
    assert any(
        (parse_bucket_key(k) or {}).get("page_size") == 1024 for k in keys
    )

    with counters.capture(
        "trace_calls", "plan_calls", "state_plan_calls", "compile_calls"
    ) as cap:
        engine, tokens = _run(
            cfg, params, _prompts(cfg, sizes=(4, 5)),
            session=PlanSession.from_manifest(str(tmp_path)),
            page_size=1024,
        )
    assert engine.memory_report.plan_source == "bundle", (
        engine.memory_report.bundle_warning
    )
    assert engine.state.paged
    assert cap.delta("trace_calls") == 0
    assert cap.delta("plan_calls") == 0
    assert cap.delta("state_plan_calls") == 0
    assert cap.delta("compile_calls") == 0, "paged AOT pack was not served"
    assert len(tokens) == 2

    # a symmetric engine must NOT pick up the paged bucket
    sym = InferenceEngine(cfg, params, n_slots=2, max_len=64,
                          session=PlanSession.from_manifest(str(tmp_path)))
    assert sym.memory_report.plan_source != "bundle"


def test_paged_meta_mismatch_is_linted(tmp_path):
    from repro.analysis import bundle_lint
    from repro.core.artifact import serve_fingerprint
    from repro.launch.compile import compile_decode_plan

    cfg = get_reduced("qwen3-0.6b")
    res = compile_decode_plan(cfg, n_slots=2, max_len=32, page_size=1024,
                              measure_xla=False, aot=False)
    sp = serve_fingerprint(page_size=1024)
    assert not [
        f for f in bundle_lint.lint_bundle(res.bundle, serve_params=sp)
        if f.severity == "error"
    ]
    # a serving context that disagrees on the page knob is flagged —
    # both a page-less context and a different page size
    for bad in (serve_fingerprint(block_size=8),
                serve_fingerprint(page_size=512)):
        findings = bundle_lint.lint_bundle(res.bundle, serve_params=bad)
        assert "paged-meta-mismatch" in {f.code for f in findings}, bad


def test_residency_off_falls_back_to_symmetric_with_warning():
    cfg = get_reduced("qwen3-0.6b")
    params = _params(cfg)
    with pytest.warns(RuntimeWarning, match="paged state requires"):
        engine = InferenceEngine(cfg, params, n_slots=2, max_len=64,
                                 page_size=1024, state_residency=False)
    assert not getattr(engine.state, "paged", False)
    engine.submit(_prompts(cfg, sizes=(4,))[0], max_new_tokens=4)
    assert len(engine.run_until_done()) == 1
