"""Paper §7 dynamic-shape protocol: staged planning with fixed history."""

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.dynamic import IncrementalPlanner
from repro.core.offsets import greedy_by_size_offsets
from repro.core.records import TensorUsageRecord
from repro.core.validate import check_offsets


def _recs(triples, base_id=0):
    return [
        TensorUsageRecord(a, b, s, tensor_id=base_id + i)
        for i, (a, b, s) in enumerate(triples)
    ]


def test_single_stage_equals_greedy_by_size():
    recs = _recs([(0, 1, 64), (1, 3, 128), (2, 4, 64), (4, 5, 256)])
    inc = IncrementalPlanner()
    inc.extend(recs)
    asn = inc.as_assignment()
    check_offsets(recs, asn)
    assert asn.total_size == greedy_by_size_offsets(recs).total_size


def test_two_stage_dynamic_resolution():
    # stage 0: static tensors; stage 1: sizes resolved mid-inference
    static = _recs([(0, 2, 256), (1, 4, 128)])
    dynamic = _recs([(3, 5, 192), (4, 6, 64)], base_id=100)
    inc = IncrementalPlanner()
    inc.extend(static)
    frozen = dict(inc.offsets)
    inc.extend(dynamic)
    # earlier placements never move (live buffers can't relocate)
    for tid, off in frozen.items():
        assert inc.offsets[tid] == off
    asn = inc.as_assignment()
    check_offsets(static + dynamic, asn)
    assert inc.n_stages == 2
    assert inc.overhead_vs_oneshot() >= 1.0


@settings(max_examples=80, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.integers(0, 10), st.integers(0, 10), st.integers(1, 256)
        ),
        min_size=1,
        max_size=16,
    ),
    st.integers(1, 4),
)
def test_staged_plans_always_valid(triples, n_stages):
    recs = [
        TensorUsageRecord(min(a, b), max(a, b), s, tensor_id=i)
        for i, (a, b, s) in enumerate(triples)
    ]
    inc = IncrementalPlanner()
    per = max(len(recs) // n_stages, 1)
    for i in range(0, len(recs), per):
        inc.extend(recs[i : i + per])
    asn = inc.as_assignment()
    check_offsets(recs, asn)
    # staging can cost memory but never correctness; bounded by naive
    assert asn.total_size <= sum(r.size for r in recs)