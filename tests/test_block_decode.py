"""Scan-block decode tests: the device-resident serving loop.

The discipline mirrors the residency differential tests: the single-wave
host loop is the oracle, and greedy block decode must be BYTE-identical
to it — same tokens per request, same slot log (admission/finish waves),
and every cache leaf bitwise-equal after the run. On-device sampling
must be reproducible under a fixed seed and — because per-slot PRNG keys
advance per emission, not per wave — invariant to the block size.
"""

import jax
import numpy as np
import pytest

from repro.analysis import counters
from repro.configs.base import get_reduced
from repro.models.api import Model
from repro.runtime.engine import InferenceEngine


def _params(cfg):
    return Model.for_config(cfg).init(jax.random.PRNGKey(0))


def _run(cfg, params, prompts, *, max_new=6, n_slots=2, max_len=64, **kw):
    engine = InferenceEngine(cfg, params, n_slots=n_slots, max_len=max_len,
                             **kw)
    for p in prompts:
        engine.submit(p, max_new_tokens=max_new)
    done = engine.run_until_done()
    tokens = {r.request_id: list(r.tokens) for r in done}
    return engine, tokens


def _prompts(cfg, sizes=(4, 6, 3, 5, 4)):
    rng = np.random.default_rng(0)
    return [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
            for n in sizes]


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-2.7b", "zamba2-7b"])
def test_greedy_block_decode_byte_identical_to_host_loop(arch):
    """The tentpole differential: multi-wave scan decode (slot reuse,
    staggered admission, on-device stop detection) against the per-wave
    host loop — tokens, slot log, and every cache leaf bitwise."""
    cfg = get_reduced(arch)
    params = _params(cfg)
    prompts = _prompts(cfg)
    host, host_tokens = _run(cfg, params, prompts)
    block, block_tokens = _run(cfg, params, prompts, block_size=4)
    assert block_tokens == host_tokens
    assert [tuple(x) for x in block.slot_log] == \
        [tuple(x) for x in host.slot_log]
    for a, b in zip(jax.tree_util.tree_leaves(host.caches),
                    jax.tree_util.tree_leaves(block.caches)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


def test_greedy_block_decode_identical_across_block_sizes():
    """Non-divisor block sizes (the block-length policy trims blocks to
    land predictable finishes on block ends) stay on the oracle's
    trajectory too."""
    cfg = get_reduced("qwen3-0.6b")
    params = _params(cfg)
    prompts = _prompts(cfg)
    _, ref = _run(cfg, params, prompts)
    for bs in (2, 3, 5, 16):
        _, got = _run(cfg, params, prompts, block_size=bs)
        assert got == ref, f"block_size={bs} diverged from the host loop"


def test_block_decode_works_with_residency_off():
    """The scan path is backend-agnostic: PytreeState (residency off)
    serves the same tokens as the donated-buffer backend."""
    cfg = get_reduced("qwen3-0.6b")
    params = _params(cfg)
    prompts = _prompts(cfg)
    on, on_tokens = _run(cfg, params, prompts, block_size=4)
    off, off_tokens = _run(cfg, params, prompts, block_size=4,
                           state_residency=False)
    assert on.state.residency and not off.state.residency
    assert on_tokens == off_tokens


def test_seeded_on_device_sampling_reproducible_and_block_invariant():
    cfg = get_reduced("qwen3-0.6b")
    params = _params(cfg)
    prompts = _prompts(cfg, sizes=(4, 5))
    kw = dict(greedy=False, temperature=0.9, top_k=20, max_new=8)
    _, a = _run(cfg, params, prompts, block_size=4, sample_seed=7, **kw)
    _, b = _run(cfg, params, prompts, block_size=4, sample_seed=7, **kw)
    assert a == b, "same seed must reproduce the sampled trajectory"
    # keys advance per EMISSION, not per wave: regrouping waves into
    # different blocks must not change the sampled tokens
    _, c = _run(cfg, params, prompts, block_size=2, sample_seed=7, **kw)
    assert a == c, "sampled decode must be invariant to the block size"
    _, d = _run(cfg, params, prompts, block_size=4, sample_seed=8, **kw)
    assert a != d, "a different seed must change the trajectory"


def test_eos_stops_on_device_matching_host_oracle():
    """EOS detection inside the scan (the device half of satellite 1):
    both paths truncate at the first EOS emission, and agree."""
    cfg = get_reduced("qwen3-0.6b")
    params = _params(cfg)
    prompts = _prompts(cfg, sizes=(4,))
    _, ref = _run(cfg, params, prompts, max_new=10)
    ref_tokens = ref[0]
    eos = ref_tokens[2]
    expect = ref_tokens[: ref_tokens.index(eos) + 1]
    for bs in (1, 8):
        _, got = _run(cfg, params, prompts, max_new=10, eos_id=int(eos),
                      block_size=bs)
        assert got[0] == expect, f"block_size={bs}"


def test_host_syncs_one_per_scan_block():
    """The counter discipline (same as zero-trace/zero-plan): the block
    path synchronizes with the host EXACTLY once per scan block; the
    host loop pays one sync per wave."""
    cfg = get_reduced("qwen3-0.6b")
    params = _params(cfg)
    prompts = _prompts(cfg)

    with counters.capture("host_syncs") as cap:
        host, _ = _run(cfg, params, prompts)
    host_syncs = cap.delta("host_syncs")
    assert host_syncs == host._wave, "host loop: one sync per wave"

    with counters.capture("host_syncs") as cap:
        block, _ = _run(cfg, params, prompts, block_size=4)
    block_syncs = cap.delta("host_syncs")
    assert block_syncs == block.n_blocks, (
        f"{block_syncs} syncs over {block.n_blocks} blocks"
    )
    assert block_syncs < host_syncs
    assert block._wave == host._wave, "both modes serve the same waves"


def test_run_until_done_exhaust_warns_in_block_mode():
    cfg = get_reduced("qwen3-0.6b")
    params = _params(cfg)
    engine = InferenceEngine(cfg, params, n_slots=1, max_len=64,
                             block_size=4)
    p = _prompts(cfg, sizes=(4,))[0]
    engine.submit(p, max_new_tokens=10)
    engine.submit(p, max_new_tokens=10)
    with pytest.warns(RuntimeWarning, match="exhausted"):
        engine.run_until_done(max_waves=6)
    assert engine._wave <= 6, "block mode must respect the wave budget"
    assert len(engine.unfinished_requests()) >= 1


def test_block_size_and_sampling_join_the_decode_fingerprint(tmp_path):
    """Bundles stay self-invalidating across serving configurations: a
    default-compiled bundle is refused by a block-decode engine (fallback
    with a fingerprint warning), and a bundle compiled for the same
    block/sampling config is served."""
    from repro.core.artifact import decode_fingerprint, serve_fingerprint
    from repro.core.unified import PlanSession
    from repro.launch.compile import compile_and_publish

    assert serve_fingerprint() is None  # default host loop: unchanged hash
    assert serve_fingerprint(block_size=1, greedy=True) is None
    # greedy canonicalizes the sampling knobs away
    assert serve_fingerprint(temperature=0.5, top_k=10) is None
    sp = serve_fingerprint(block_size=8)
    assert sp is not None
    cfg = get_reduced("qwen3-0.6b")
    fp = decode_fingerprint(cfg, n_slots=2, max_len=64)
    assert fp != decode_fingerprint(cfg, n_slots=2, max_len=64,
                                    serve_params=sp)
    assert fp == decode_fingerprint(cfg, n_slots=2, max_len=64,
                                    serve_params=None)

    params = _params(cfg)
    default_dir = tmp_path / "default"
    compile_and_publish(cfg, str(default_dir), n_slots=2, max_len=64,
                        measure_xla=False)
    engine = InferenceEngine(
        cfg, params, n_slots=2, max_len=64, block_size=8,
        session=PlanSession.from_manifest(str(default_dir)),
    )
    assert engine.memory_report.plan_source != "bundle"
    assert "fingerprint mismatch" in (engine.memory_report.bundle_warning or "")

    block_dir = tmp_path / "block"
    compile_and_publish(cfg, str(block_dir), n_slots=2, max_len=64,
                        block_size=8, measure_xla=False)
    engine2 = InferenceEngine(
        cfg, params, n_slots=2, max_len=64, block_size=8,
        session=PlanSession.from_manifest(str(block_dir)),
    )
    assert engine2.memory_report.plan_source == "bundle", (
        engine2.memory_report.bundle_warning
    )
    # and it serves correctly off the bundle
    engine2.submit(_prompts(cfg, sizes=(4,))[0], max_new_tokens=8)
    done = engine2.run_until_done()
    assert len(done) == 1 and len(done[0].tokens) == 8
