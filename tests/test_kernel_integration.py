"""flash_decode kernel wired into the model decode path must match the
XLA attn_decode bit-for-tolerance (framework-level kernel integration)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn


@pytest.mark.parametrize("B,H,KV,D,T", [(2, 4, 2, 64, 128), (3, 8, 1, 64, 256)])
def test_attn_decode_kernel_matches_xla(B, H, KV, D, T):
    key = jax.random.PRNGKey(0)
    p = attn.attn_init(key, H * D, H, KV, D, False, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 1, H * D)) * 0.1
    k_cache = jax.random.normal(jax.random.PRNGKey(2), (B, T, KV, D)) * 0.1
    v_cache = jax.random.normal(jax.random.PRNGKey(3), (B, T, KV, D)) * 0.1
    pos = jnp.array([T // 2 + i for i in range(B)], jnp.int32)
    kwargs = dict(n_heads=H, n_kv=KV, head_dim=D, theta=10_000.0, window=None)

    out_ref, (k_ref, v_ref) = attn.attn_decode(
        p, x, (k_cache, v_cache), pos, **kwargs
    )
    out_k, (k_k, v_k) = attn.attn_decode_kernel(
        p, x, (k_cache, v_cache), pos, interpret=True, **kwargs
    )
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(k_k), np.asarray(k_ref))
    np.testing.assert_array_equal(np.asarray(v_k), np.asarray(v_ref))


def test_attn_decode_kernel_respects_active_mask():
    B, H, KV, D, T = 2, 2, 1, 64, 64
    p = attn.attn_init(jax.random.PRNGKey(0), H * D, H, KV, D, False, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 1, H * D)) * 0.1
    kc = jnp.zeros((B, T, KV, D))
    vc = jnp.zeros((B, T, KV, D))
    pos = jnp.array([5, 9], jnp.int32)
    active = jnp.array([True, False])
    _, (k_new, _) = attn.attn_decode_kernel(
        p, x, (kc, vc), pos, n_heads=H, n_kv=KV, head_dim=D,
        theta=10_000.0, window=None, active=active, interpret=True,
    )
    assert float(jnp.abs(k_new[0, 5]).sum()) > 0  # active row wrote
    assert float(jnp.abs(k_new[1]).sum()) == 0  # frozen row untouched