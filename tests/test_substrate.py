"""Substrate tests: data pipeline, AdamW, checkpointing, mesh rules."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim import adamw


def test_pipeline_deterministic_and_seekable():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    b1, b2 = p1.batch_at(7), p2.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    # different steps differ
    assert not np.array_equal(p1.batch_at(8)["tokens"], b1["tokens"])


def test_pipeline_host_sharding():
    full = TokenPipeline(DataConfig(vocab=50, seq_len=8, global_batch=8))
    h0 = TokenPipeline(DataConfig(vocab=50, seq_len=8, global_batch=8, n_hosts=2, host_id=0))
    h1 = TokenPipeline(DataConfig(vocab=50, seq_len=8, global_batch=8, n_hosts=2, host_id=1))
    assert h0.per_host == 4 and h1.per_host == 4
    assert not np.array_equal(h0.batch_at(0)["tokens"], h1.batch_at(0)["tokens"])


def test_adamw_reduces_loss_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw.init_state(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state, metrics = adamw.apply_updates(cfg, params, g, state)
    assert float(loss(params)) < 0.1
    assert float(metrics["lr"]) > 0


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"c": jnp.array([1, 2], jnp.int32)},
    }
    path = os.path.join(tmp_path, "ck")
    ckpt.save(path, tree, meta={"step": 42})
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    restored, meta = ckpt.restore(path, like)
    assert meta["step"] == 42
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]), np.asarray(tree["b"]["c"]))
    # structure mismatch is caught
    with pytest.raises(ValueError):
        ckpt.restore(path, {"x": tree["a"]})


def test_training_loss_decreases():
    from repro.launch.train import run_training

    hist = run_training("qwen3-0.6b", steps=12, seq_len=64, batch=4)
    assert hist[-1]["loss"] < hist[0]["loss"], (
        f"loss did not drop: {hist[0]['loss']} -> {hist[-1]['loss']}"
    )


def test_training_checkpoint_resume_is_exact(tmp_path):
    """save at step 4, resume, and match the uninterrupted run exactly
    (the pipeline is seekable, so state = params+opt+step)."""
    from repro.launch.train import run_training

    path = os.path.join(tmp_path, "ck")
    full = run_training("qwen3-0.6b", steps=8, seq_len=32, batch=2)
    run_training("qwen3-0.6b", steps=4, seq_len=32, batch=2,
                 ckpt_path=path, save_every=4)
    resumed = run_training("qwen3-0.6b", steps=8, seq_len=32, batch=2,
                           ckpt_path=path)
    assert len(resumed) == 4  # steps 4..7
    for a, b in zip(full[4:], resumed):
        assert abs(a["loss"] - b["loss"]) < 1e-4, (a["loss"], b["loss"])
