"""Sharding-rule unit tests (pure spec logic; no multi-device runtime)."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.launch.mesh import ShardingCtx, make_test_mesh


@pytest.fixture(scope="module")
def ctx():
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeShape:
        # spec logic only consults mesh.shape; build a 16x16-shaped view
        shape = {"data": 16, "model": 16}
        size = 256

    # use a real Mesh but with the logical sizes we care about via a stub
    return ShardingCtx(_StubMesh(), get_config("qwen3-0.6b"))


class _StubMesh:
    shape = {"data": 16, "model": 16}
    size = 256


def _spec(ctx, shape, tag):
    return ctx.activation_spec(jnp.zeros(shape) if False else _Arr(shape), tag)


class _Arr:
    def __init__(self, shape):
        self.shape = shape
        self.ndim = len(shape)


def test_batch_axis_resolution(ctx):
    assert ctx.batch_axis_for(256) == ("data",)
    assert ctx.batch_axis_for(1) is None
    assert ctx.batch_axis_for(32) == ("data",)
    assert ctx.batch_axis_for(7) is None


def test_heads_never_shard_head_dim(ctx):
    # 40 heads % 16 != 0 -> replicate heads AND head_dim (llama4 case)
    spec = _spec(ctx, (32, 128, 40, 128), "heads")
    assert spec == P(("data",), None, None, None)
    # divisible heads -> shard heads
    spec = _spec(ctx, (32, 128, 32, 128), "heads")
    assert spec == P(("data",), None, "model", None)


def test_kv_context_parallel_fallback(ctx):
    # kv=8 not divisible -> shard the sequence dim (context parallel)
    spec = _spec(ctx, (32, 4096, 8, 128), "kv_heads")
    assert spec == P(("data",), "model", None, None)
    # kv=16 divisible -> shard kv heads
    spec = _spec(ctx, (32, 4096, 16, 128), "kv_heads")
    assert spec == P(("data",), None, "model", None)


def test_seq_parallel_hidden():
    ctx_sp = ShardingCtx(_StubMesh(), get_config("qwen3-0.6b"), seq_parallel=True)
    spec = ctx_sp.activation_spec(_Arr((16, 4096, 1024)), "hidden")
    assert spec == P(("data",), "model", None)
    # decode (S=1): no seq sharding
    spec = ctx_sp.activation_spec(_Arr((16, 1, 1024)), "hidden")
    assert spec == P(("data",), None, None)


def test_param_spec_rules(ctx):
    spec = ctx.param_spec("period/0/attn/wq", _Arr((28, 1024, 1024)))
    assert spec == P(None, "data", "model")
    spec = ctx.param_spec("embed", _Arr((151936, 1024)))
    assert spec == P("model", "data")
    # moe experts divisible -> expert axis
    spec = ctx.param_spec("period/0/moe/w_in", _Arr((24, 128, 5120, 8192)))
    assert spec[1] == "model"  # 128 experts over model
    # norm scales replicate
    spec = ctx.param_spec("ln_f", _Arr((1024,)))
    assert spec == P(None)