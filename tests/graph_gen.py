"""Random usage-record generators for the differential-test harness.

Plain-``random`` generator functions (no hypothesis dependency — the
differential oracle harness must run everywhere the repo runs); when
hypothesis IS installed, :func:`hypothesis_records` wraps the same
generators as a strategy so shrinking works on property tests too.

Four synthetic families stress different planner regimes, and
:func:`config_records` traces every REDUCED model config in
``src/repro/configs/`` to real transformer/SSM/MoE decode-stack graphs:

* ``uniform``  — i.i.d. intervals and sizes (the classic fuzz case)
* ``chain``    — producer->consumer pipelines with skip connections
               (DNN-like: short intervals + a few long skips)
* ``layered``  — transformer-shaped: per-layer short-lived activations
               plus residual-stream tensors spanning whole layers
* ``ties``     — few distinct (aligned) sizes and heavy interval sharing:
               adversarial for tie-breaking equivalence, where a fast
               reimplementation is most likely to drift from the oracle
"""

from __future__ import annotations

import functools
import random
from typing import Callable

from repro.core.records import TensorUsageRecord


def uniform_records(
    seed: int, n: int | None = None, max_ops: int = 24, max_size: int = 512
) -> list[TensorUsageRecord]:
    rng = random.Random(seed)
    n = n or rng.randrange(1, 48)
    recs = []
    for i in range(n):
        a = rng.randrange(max_ops)
        b = rng.randrange(a, max_ops)
        recs.append(
            TensorUsageRecord(a, b, rng.randrange(1, max_size), tensor_id=i)
        )
    return recs


def chain_records(seed: int, n: int | None = None) -> list[TensorUsageRecord]:
    rng = random.Random(seed)
    n = n or rng.randrange(2, 40)
    recs = []
    for i in range(n):
        first = i
        # mostly consumed by the next op; occasionally a long skip edge
        last = i + (rng.randrange(2, 12) if rng.random() < 0.2 else 1)
        recs.append(
            TensorUsageRecord(
                first, min(last, n + 11), rng.choice([64, 128, 256, 384]),
                tensor_id=i,
            )
        )
    return recs


def layered_records(seed: int, n_layers: int | None = None) -> list[TensorUsageRecord]:
    rng = random.Random(seed)
    n_layers = n_layers or rng.randrange(1, 8)
    ops_per_layer = 5
    recs = []
    tid = 0
    for layer in range(n_layers):
        base = layer * ops_per_layer
        # residual stream: lives across the whole layer
        recs.append(
            TensorUsageRecord(base, base + ops_per_layer, 256, tensor_id=tid)
        )
        tid += 1
        # short-lived per-layer activations (qkv, mlp hidden, etc.)
        for j in range(rng.randrange(2, 6)):
            a = base + rng.randrange(ops_per_layer)
            b = min(a + rng.randrange(1, 3), base + ops_per_layer)
            recs.append(
                TensorUsageRecord(
                    a, b, rng.choice([128, 512, 1024]), tensor_id=tid
                )
            )
            tid += 1
    return recs


def ties_records(seed: int, n: int | None = None) -> list[TensorUsageRecord]:
    rng = random.Random(seed)
    n = n or rng.randrange(4, 56)
    sizes = [64, 64, 64, 128, 128, 256]  # heavy duplication on purpose
    max_ops = max(4, n // 3)
    recs = []
    for i in range(n):
        a = rng.randrange(max_ops)
        b = rng.randrange(a, max_ops)
        recs.append(TensorUsageRecord(a, b, rng.choice(sizes), tensor_id=i))
    return recs


GENERATORS: dict[str, Callable[[int], list[TensorUsageRecord]]] = {
    "uniform": uniform_records,
    "chain": chain_records,
    "layered": layered_records,
    "ties": ties_records,
}


def generate(kind: str, seed: int) -> list[TensorUsageRecord]:
    return GENERATORS[kind](seed)


@functools.lru_cache(maxsize=None)
def config_records(arch: str) -> tuple[TensorUsageRecord, ...]:
    """Usage records of the REDUCED config's forward graph (shape-level
    trace; no parameters are materialized). Cached per session — several
    test modules sweep the same ten graphs."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_reduced
    from repro.models.api import Model
    from repro.trace.jaxpr_liveness import trace_graph

    cfg = get_reduced(arch)
    model = Model.for_config(cfg)
    B, S = 2, 16
    sds = jax.ShapeDtypeStruct
    batch: dict = {"tokens": sds((B, S), jnp.int32)}
    if cfg.n_prefix_tokens:
        batch["prefix_embeds"] = sds(
            (B, cfg.n_prefix_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    if cfg.family == "audio":
        batch["frames"] = sds(
            (B, max(S // cfg.enc_len_ratio, 1), cfg.d_model), jnp.dtype(cfg.dtype)
        )
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    graph = trace_graph(
        lambda p, b: model.forward(p, b), params, batch, name=f"{arch}-fwd"
    )
    return tuple(graph.usage_records())


def hypothesis_records():
    """Optional hypothesis strategy over all generator families."""
    from hypothesis import strategies as st

    return st.builds(
        generate,
        st.sampled_from(sorted(GENERATORS)),
        st.integers(min_value=0, max_value=1 << 20),
    )
